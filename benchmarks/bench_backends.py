"""Backend matrix + batched-PPR throughput (the serving-shape numbers).

Three questions this answers on any hardware:

  1. Push-backend comparison — same solve, same graph, each registered
     ``step_impl``: wall time, iteration count and the hardware-independent
     operation count M(T).  The frontier row also reports the *edge-visit*
     saving (its compressed working set vs. m x iterations).
  2. Batched-PPR amortisation — solving B personalized queries in one
     batched pass vs. B sequential solves.  The ratio is the serving win:
     the edge stream is read once per iteration for the whole batch.
  3. Engine serving throughput — the same B queries answered by a prepared
     :class:`PageRankEngine` (one ``solve_batch`` pass against cached
     classification/bucketing/ctx) vs. B one-shot engines built per call,
     each re-deriving that state every time (the shape the removed
     ``solve_pagerank`` funnel executed).  This is the
     prepare-once/query-many ratio the engine exists for; the acceptance
     bar is ≥ 2x.
  4. Sharded serving — the same seed stream through an engine prepared
     with ``EnginePlan(mesh=(n_dev, 1))`` vs. the single-device engine
     (skipped on one device).  ``--sharded-json PATH`` records this
     comparison as a JSON baseline (``benchmarks/BENCH_ppr_sharded.json``
     is the committed 8-simulated-device entry); on a host mesh all
     "devices" share one CPU and the (R, 1) layout replicates the edge
     stream, so speedup < 1 is expected — the row tracks correctness
     (bit_identical) + overhead, not speedup, which needs real devices.
  5. Query-plane overhead — ``engine.run(query)`` (plan + envelope around
     the same compute) vs. the direct solver call with the engine's
     prepared ctx, which is exactly what the pre-redesign methods
     executed.  ``--query-plan-json PATH`` records it
     (``benchmarks/BENCH_query_plan.json`` is the committed baseline);
     the acceptance bar is overhead ≤ 2%.
  6. Sharded-ELL vs dense sharded — the two vertex-sharded (C > 1)
     schedules on the same (R, 2) grid against the single-device batch.
     ``--ell-sharded-json PATH`` records it
     (``benchmarks/BENCH_ell_sharded.json`` is the committed entry); the
     record is the agreement (``within_tol``) + overhead baseline —
     interpret-mode Pallas wall-clock on a host mesh is a correctness
     harness, not a speed claim.
  7. Planner cost provenance — the same graph planned against an empty
     roofline cost table (declared-constants fallback) and against a
     table with a measured sample per backend (measured re-ranking);
     ``--planner-costs-json PATH`` records the two decisions, their
     agreement, and the ``plan.explain()`` provenance booleans
     (``benchmarks/BENCH_planner_costs.json`` is the committed entry).
     Everything in the record derives from deterministic HLO lowerings
     priced by ``repro.roofline.hw``, so the booleans are exact per
     platform — see docs/ROOFLINE.md.

Committed ``BENCH_*.json`` baselines are schema-checked in CI by
``benchmarks/check_bench_schema.py``, and the CI ``bench-drift`` job
re-runs the JSON modes with ``--smoke`` (shrunk graph/batch) and
drift-checks the fresh records against the committed ones with
``check_bench_schema.py --compare`` — baselines are read on every PR,
not write-only.

CPU wall-clock caveats from benchmarks/common.py apply (interpret-mode
Pallas is Python-slow by construction); iteration/op counts transfer.
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.core import (
    BatchConfig,
    EnginePlan,
    ItaConfig,
    PageRankEngine,
    available_step_impls,
    ita,
    one_hot_personalizations,
    solve_pagerank_batch,
)
from repro.graph import web_graph

from .common import csv_row, timed


def run(datasets=None) -> list[str]:
    rows = []
    g = web_graph(20_000, 160_000, dangling_frac=0.15, seed=7)

    # 1. backend matrix on one solve
    for impl in available_step_impls():
        r, best = timed(ita, g, xi=1e-10, step_impl=impl, repeats=2)
        rows.append(csv_row(
            f"backend/{impl}", best * 1e6,
            f"iters={r.iterations} ops={r.ops:.3e} converged={r.converged}"))

    # 2. batched PPR vs sequential
    B = 16
    seeds = np.random.default_rng(0).choice(g.n, size=B, replace=False)
    P = one_hot_personalizations(g, seeds)
    # repeats=2 so neither side pays one-time trace/compile in the ratio
    rb, t_batch = timed(solve_pagerank_batch, g, P, method="ita", xi=1e-10,
                        repeats=2)
    t0 = time.perf_counter()
    for i in range(B):
        jax.block_until_ready(ita(g, p=P[i], xi=1e-10).pi)
    t_seq = time.perf_counter() - t0
    rows.append(csv_row(
        f"ppr_batch/B{B}", t_batch * 1e6,
        f"seq_us={t_seq * 1e6:.1f} speedup={t_seq / max(t_batch, 1e-12):.2f}x "
        f"iters={rb.iterations}"))

    # 3. engine serving throughput vs the per-call legacy path
    engine = PageRankEngine(g, EnginePlan(step_impl="dense"))
    cfg = BatchConfig(xi=1e-10)
    # repeats=2: the engine side measures steady-state serving (trace warm)
    rb, t_engine = timed(engine.solve_batch, P, cfg, repeats=2)
    # the one-shot side builds an engine per call — the state re-derivation
    # the removed solve_pagerank funnel paid on every query
    t_legacy = float("inf")
    for _ in range(2):
        t0 = time.perf_counter()
        for i in range(B):
            one_shot = PageRankEngine(g, EnginePlan(step_impl="dense"))
            jax.block_until_ready(
                one_shot.solve(ItaConfig(p=P[i], xi=1e-10)).pi)
        t_legacy = min(t_legacy, time.perf_counter() - t0)
    rows.append(csv_row(
        f"engine_serving/B{B}", t_engine * 1e6,
        f"legacy_us={t_legacy * 1e6:.1f} "
        f"speedup={t_legacy / max(t_engine, 1e-12):.2f}x "
        f"qps={B / max(t_engine, 1e-12):.1f}"))

    # 3b. prepare amortisation in isolation: repeated single solves on the
    # frontier backend, whose per-graph CSR plan is the prepare-heavy one.
    engine_f = PageRankEngine(g, EnginePlan(step_impl="frontier"))
    r1, t_eng1 = timed(engine_f.solve, ItaConfig(xi=1e-10), repeats=2)

    def _one_shot_frontier():
        return PageRankEngine(g, EnginePlan(step_impl="frontier")).solve(
            ItaConfig(xi=1e-10))

    _, t_leg1 = timed(_one_shot_frontier, repeats=2)
    rows.append(csv_row(
        "engine_repeat/frontier", t_eng1 * 1e6,
        f"legacy_us={t_leg1 * 1e6:.1f} "
        f"speedup={t_leg1 / max(t_eng1, 1e-12):.2f}x iters={r1.iterations}"))

    # 4. sharded serving vs single-device (needs > 1 device); reuse the
    # graph and seed stream already built above
    if len(jax.devices()) > 1:
        s = run_sharded(B=B, graph=g, p_batch=P)
        rows.append(csv_row(
            f"ppr_sharded/B{B}x{s['devices']}dev", s["sharded_us"],
            f"single_us={s['single_us']:.1f} speedup={s['speedup']:.2f}x "
            f"bitwise={s['bit_identical']} iters={s['iterations']}"))
    return rows


def run_sharded(B: int = 16, *, n: int = 20_000, m: int = 160_000,
                xi: float = 1e-10, seed: int = 7, graph=None,
                p_batch=None) -> dict:
    """Single-device vs mesh-sharded engine serving on the same seed stream.

    Returns the JSON-ready comparison dict; the mesh is the (n_dev, 1)
    batch-parallel grid over everything ``jax.devices()`` offers, so under
    XLA_FLAGS=--xla_force_host_platform_device_count=8 this is the CI
    distributed-serving baseline.  Bit-identity of the two answers is part
    of the record — a perf row that silently changed numerics is worthless.
    """
    n_dev = len(jax.devices())
    if n_dev < 2:
        raise SystemExit(
            "run_sharded needs > 1 device — a (1, 1) comparison would "
            "record a baseline that never sharded anything; set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    g = graph if graph is not None else web_graph(n, m, dangling_frac=0.15,
                                                  seed=seed)
    if p_batch is None:
        seeds = np.random.default_rng(0).choice(g.n, size=B, replace=False)
        P = one_hot_personalizations(g, seeds)
    else:
        P = p_batch
    cfg = BatchConfig(xi=xi)

    e_single = PageRankEngine(g, EnginePlan(step_impl="dense"))
    r_single, t_single = timed(e_single.solve_batch, P, cfg, repeats=2)

    e_mesh = PageRankEngine(g, EnginePlan(step_impl="dense",
                                          mesh=(n_dev, 1)))
    r_mesh, t_mesh = timed(e_mesh.solve_batch, P, cfg, repeats=2)

    return dict(
        bench="ppr_sharded",
        graph=dict(n=g.n, m=g.m),
        batch=B,
        seed_stream=dict(rng_seed=0, graph_seed=seed),
        xi=xi,
        devices=n_dev,
        mesh=[n_dev, 1],
        platform=jax.default_backend(),
        single_us=t_single * 1e6,
        sharded_us=t_mesh * 1e6,
        speedup=t_single / max(t_mesh, 1e-12),
        qps_sharded=B / max(t_mesh, 1e-12),
        iterations=int(r_mesh.iterations),
        bit_identical=bool(jax.numpy.array_equal(r_single.pi, r_mesh.pi)),
        method=r_mesh.method,
        note="simulated host mesh: all devices share one CPU and the "
             "(R, 1) layout replicates the edge stream, so total CPU work "
             "RISES ~Rx while per-device work drops 1/R — expect speedup "
             "< 1 here; the record is the correctness + overhead baseline "
             "(bit_identical must stay true), realized speedup needs real "
             "multi-device hardware",
    )


def run_ell_sharded(B: int = 8, *, n: int = 4_000, m: int = 24_000,
                    xi: float = 1e-8, seed: int = 7,
                    tol: float = 1e-8) -> dict:
    """Sharded-ELL vs dense sharded vs single-device on an (R, 2) grid.

    Default sizes are deliberately small: off-TPU the ELL kernel runs
    interpret-mode (Python-slow by construction), so this record tracks
    *agreement* of the two vertex-sharded schedules — ``within_tol`` must
    stay true — plus their relative overhead, not absolute speed.  The
    mesh is (n_dev // 2, 2): the widest batch axis that still exercises
    C = 2 vertex sharding on whatever the host offers.
    """
    n_dev = len(jax.devices())
    if n_dev < 2:
        raise SystemExit(
            "run_ell_sharded needs > 1 device for a C=2 vertex-sharded "
            "grid; set XLA_FLAGS=--xla_force_host_platform_device_count=8")
    from repro.core.distributed import ita_batch_distributed, resolve_mesh
    from repro.core import ita_batch

    g = web_graph(n, m, dangling_frac=0.15, seed=seed)
    seeds = np.random.default_rng(0).choice(g.n, size=B, replace=False)
    P = one_hot_personalizations(g, seeds)
    mesh_shape = (n_dev // 2, 2)
    mesh = resolve_mesh(mesh_shape)

    r_single, t_single = timed(ita_batch, g, P, xi=xi, repeats=2)
    r_dense, t_dense = timed(ita_batch_distributed, g, P, mesh, xi=xi,
                             step_impl="dense", repeats=2)
    r_ell, t_ell = timed(ita_batch_distributed, g, P, mesh, xi=xi,
                         step_impl="ell", repeats=2)
    err_vs_dense = float(jax.numpy.max(jax.numpy.abs(r_ell.pi - r_dense.pi)))
    err_vs_single = float(jax.numpy.max(jax.numpy.abs(r_ell.pi - r_single.pi)))
    return dict(
        bench="ell_sharded",
        graph=dict(n=g.n, m=g.m),
        batch=B,
        xi=xi,
        tol=tol,
        devices=n_dev,
        mesh=list(mesh_shape),
        platform=jax.default_backend(),
        single_us=t_single * 1e6,
        dense_sharded_us=t_dense * 1e6,
        ell_sharded_us=t_ell * 1e6,
        err_ell_vs_dense=err_vs_dense,
        err_ell_vs_single=err_vs_single,
        within_tol=bool(err_vs_dense < tol and err_vs_single < tol),
        iterations=int(r_ell.iterations),
        method=r_ell.method,
        note="simulated host mesh + interpret-mode Pallas: the record is "
             "the agreement baseline for the two vertex-sharded schedules "
             "(within_tol must stay true); wall-clock ratios off-TPU are "
             "an interpreter artifact, realized kernel speed needs "
             "compiled Mosaic on real devices",
    )


def run_query_plan(B: int = 16, *, n: int = 20_000, m: int = 160_000,
                   xi: float = 1e-10, seed: int = 7) -> dict:
    """``engine.run(query)`` vs. the direct solver call, same prepared ctx.

    The direct side is the module-level solver with the engine's prepared
    backend context threaded in — bit-for-bit the compute the legacy
    methods drove before the query plane existed.  The run side adds
    planning + envelope wrapping; the committed bar is ≤ 2% overhead.
    Negative overhead just means the difference drowned in timer noise.
    """
    from repro.core import PPRQuery, RankQuery, ita_batch

    g = web_graph(n, m, dangling_frac=0.15, seed=seed)
    seeds = np.random.default_rng(0).choice(g.n, size=B, replace=False)
    P = one_hot_personalizations(g, seeds)
    cfg = BatchConfig(xi=xi)
    rank_cfg = ItaConfig(xi=xi)
    engine = PageRankEngine(g, EnginePlan(step_impl="dense"))

    # batched PPR: direct ita_batch(ctx=prepared) vs run(PPRQuery)
    rb_direct, t_direct = timed(
        ita_batch, g, P, xi=xi, step_impl="dense", ctx=engine._ctx,
        repeats=3)
    rb_run, t_run = timed(
        lambda: engine.run(PPRQuery(p_batch=P, cfg=cfg)).result, repeats=3)
    # single rank: direct ita(ctx=prepared) vs run(RankQuery)
    r_direct, t_rank_direct = timed(
        ita, g, xi=xi, step_impl="dense", ctx=engine._ctx, repeats=3)
    r_run, t_rank_run = timed(
        lambda: engine.run(RankQuery(rank_cfg)).result, repeats=3)

    overhead = (t_run / max(t_direct, 1e-12) - 1.0) * 100.0
    rank_overhead = (t_rank_run / max(t_rank_direct, 1e-12) - 1.0) * 100.0
    plan_text = engine.plan(PPRQuery(p_batch=P, cfg=cfg)).explain()
    return dict(
        bench="query_plan",
        graph=dict(n=g.n, m=g.m),
        batch=B,
        xi=xi,
        platform=jax.default_backend(),
        direct_us=t_direct * 1e6,
        run_us=t_run * 1e6,
        overhead_pct=overhead,
        rank_direct_us=t_rank_direct * 1e6,
        rank_run_us=t_rank_run * 1e6,
        rank_overhead_pct=rank_overhead,
        within_2pct=bool(overhead <= 2.0 and rank_overhead <= 2.0),
        bit_identical=bool(
            jax.numpy.array_equal(rb_direct.pi, rb_run.pi)
            and jax.numpy.array_equal(r_direct.pi, r_run.pi)),
        plan=plan_text.splitlines()[0],
        note="run side = plan + envelope around the identical prepared-ctx "
             "compute; best-of-3 wall times, CPU caveats from "
             "benchmarks/common.py apply",
    )


def run_planner_costs(B: int = 8, *, n: int = 4_000, m: int = 24_000,
                      xi: float = 1e-10, seed: int = 7) -> dict:
    """Measured-vs-declared planner decisions + explain() provenance.

    Two passes over the same graph: first the planner decides with an
    EMPTY cost table pinned (the declared-constants fallback every fresh
    checkout runs on), then with a table holding a ``measure_step`` sample
    for every registered backend (full coverage, so ``choose_backend``
    re-ranks by measured roofline seconds).  The record captures both
    decisions, whether they agree, and the provenance strings each
    ``plan.explain()`` must quote — these are deterministic lowerings
    priced by the roofline model, not wall-clock, so every boolean is
    reproducible on a given platform.  Defaults ARE the smoke sizes.
    """
    from repro.core import RankQuery
    from repro.core.backends import choose_backend
    from repro.roofline import CostTable, measure_step, set_cost_table
    from repro.roofline.planner_costs import plan_cost

    g = web_graph(n, m, dangling_frac=0.15, seed=seed)
    stats = dict(n=g.n, m=g.m, dtype="float64")
    cfg = ItaConfig(xi=xi)
    q = RankQuery(cfg)
    try:
        # declared pass: empty table pinned -> the fallback path, exercised
        set_cost_table(CostTable())
        decl_name, decl_reason = choose_backend(stats)
        decl_plan = PageRankEngine(g, EnginePlan(step_impl="auto")).plan(q)
        decl_text = decl_plan.explain()
        pc_decl = plan_cost(decl_name, stats, cfg)

        # measured pass: one sample per registered backend = full coverage
        table = CostTable()
        samples = {name: measure_step(name, g, dtype="float64")
                   for name in ("dense", "ell", "frontier")}
        for s in samples.values():
            table.add(s)
        set_cost_table(table)
        meas_name, meas_reason = choose_backend(stats)
        meas_plan = PageRankEngine(g, EnginePlan(step_impl="auto")).plan(q)
        meas_text = meas_plan.explain()
        pc_meas = plan_cost(decl_name, stats, cfg)
        pc_meas_b = plan_cost(decl_name, stats, cfg, batch=B)
    finally:
        set_cost_table(None)

    return dict(
        bench="planner_costs",
        graph=dict(n=g.n, m=g.m),
        batch=B,
        xi=xi,
        platform=jax.default_backend(),
        decision_declared=decl_name,
        decision_measured=meas_name,
        decision_agreement=bool(decl_name == meas_name),
        declared_reason_ok=bool(
            "lowest est. cost among eligible backends" in decl_reason),
        measured_reason_ok=bool(
            "lowest measured roofline cost" in meas_reason
            and "cost source: measured" in meas_reason),
        declared_provenance=bool(
            "cost source: declared" in decl_text
            and "declared backend cost constants" in decl_text),
        measured_provenance=bool(
            "cost source: measured" in meas_text
            and "measured roofline sample" in meas_text),
        # plan.cost must stay in declared edge-traversal units whatever the
        # source (the serving CostModel calibrates against those units)
        cost_units_stable=bool(
            pc_meas.source == "measured" and pc_meas.cost == pc_decl.cost
            and pc_meas_b.cost == B * pc_decl.cost),
        dense_seconds=float(samples["dense"].seconds),
        ell_seconds=float(samples["ell"].seconds),
        frontier_seconds=float(samples["frontier"].seconds),
        dense_bytes=float(samples["dense"].bytes_accessed),
        ell_bytes=float(samples["ell"].bytes_accessed),
        plan=meas_text.splitlines()[0],
        note="decisions + provenance from deterministic HLO lowerings "
             "priced by roofline/hw.py, not wall-clock; *_seconds are "
             "modeled seconds per push round on this platform; defaults "
             "are the smoke sizes so CI re-runs the committed shape",
    )


def run_serving_cache(B: int = 8, *, n: int = 4_000, m: int = 24_000,
                      xi: float = 1e-8, seed: int = 7, queries: int = 160,
                      zipf: float = 1.5, k: int = 5,
                      tol: float = 1e-6) -> dict:
    """Zipf serving stream through a cached vs uncached engine.

    Steady-state shape: the cache is warmed with one stream, then a FRESH
    stream drawn from the same Zipf law is measured on both engines — so
    the recorded hit rate is the honest mixed hit/miss rate of continued
    serving, not a replay of identical requests.  After the measured
    window an edge delta lands on both sides and the stream re-serves:
    every stale entry refreshes through ``ita_incremental``
    (``revalidated_frac``), and the refreshed answers are checked against
    a from-scratch engine on the delta'd graph (``reval_err`` /
    ``within_tol``).  ``bit_identical`` asserts the measured hot pass
    returned exactly the uncached engine's bits, hits and misses alike.
    """
    from repro.core import CachePolicy, TopKQuery
    from repro.serve.workload import zipf_seeds

    g = web_graph(n, m, dangling_frac=0.15, seed=seed)
    cfg = BatchConfig(xi=xi)
    rng = np.random.default_rng(0)
    # warm with 3x the measured traffic: a micro-batch only skips the
    # device pass when ALL B rows hit, so the p50 win needs the hot set
    # to cover most of the stream — exactly the steady-state a serving
    # cache reaches after a few minutes of Zipf traffic.
    warm_stream = zipf_seeds(g, 3 * queries, zipf, rng)
    stream = zipf_seeds(g, queries, zipf, rng)

    e_cold = PageRankEngine(g, EnginePlan(step_impl="dense"))
    e_hot = PageRankEngine(g, EnginePlan(step_impl="dense",
                                         cache=CachePolicy()))

    def serve(engine, seeds):
        lats, answers = [], []
        for lo in range(0, len(seeds), B):
            req = seeds[lo:lo + B]
            t0 = time.perf_counter()
            env = engine.run(TopKQuery(sources=req, k=k, cfg=cfg))
            jax.block_until_ready(env.result.scores)
            lats.append((time.perf_counter() - t0) / len(req))
            answers.append((np.asarray(env.result.indices),
                            np.asarray(env.result.scores)))
        return np.asarray(lats) * 1e6, answers

    # compile outside the measured window, then warm the cache with the
    # first stream (the "yesterday's traffic" the hot engine has seen)
    e_cold.run(TopKQuery(sources=warm_stream[:B], k=k, cfg=cfg))
    serve(e_hot, warm_stream)
    s_warm = e_hot.result_cache.stats()

    lat_cold, ans_cold = serve(e_cold, stream)
    lat_hot, ans_hot = serve(e_hot, stream)
    s_meas = e_hot.result_cache.stats()
    hits = s_meas["hits"] - s_warm["hits"]
    misses = s_meas["misses"] - s_warm["misses"]
    hit_rate = hits / max(hits + misses, 1)
    bit_identical = all(
        np.array_equal(ic, ih) and np.array_equal(sc, sh)
        for (ic, sc), (ih, sh) in zip(ans_cold, ans_hot))
    p50_cold = float(np.percentile(lat_cold, 50))
    p50_hot = float(np.percentile(lat_hot, 50))

    # an edge delta lands; the re-served stream revalidates stale entries
    edge_set = set(zip(np.asarray(g.src).tolist(), np.asarray(g.dst).tolist()))
    add = []
    while len(add) < 4:
        a, b = int(rng.integers(n)), int(rng.integers(n))
        if a != b and (a, b) not in edge_set and (a, b) not in add:
            add.append((a, b))
    e_hot.update(add=add)
    serve(e_hot, stream)
    s_post = e_hot.result_cache.stats()
    revalidated_frac = (s_post["revalidated"] - s_meas["revalidated"]) / queries
    e_fresh = PageRankEngine(e_hot.graph, EnginePlan(step_impl="dense"))
    probe = stream[:B]
    sc_hot = np.asarray(
        e_hot.run(TopKQuery(sources=probe, k=k, cfg=cfg)).result.scores)
    sc_fresh = np.asarray(
        e_fresh.run(TopKQuery(sources=probe, k=k, cfg=cfg)).result.scores)
    reval_err = float(np.max(np.abs(sc_hot - sc_fresh)))

    return dict(
        bench="serving_cache",
        graph=dict(n=g.n, m=g.m),
        batch=B,
        queries=queries,
        zipf=zipf,
        k=k,
        xi=xi,
        tol=tol,
        platform=jax.default_backend(),
        p50_cold_us=p50_cold,
        p50_hot_us=p50_hot,
        speedup_p50=p50_cold / max(p50_hot, 1e-12),
        hit_rate=float(hit_rate),
        revalidated_frac=float(revalidated_frac),
        reval_err=reval_err,
        within_tol=bool(reval_err < tol),
        bit_identical=bool(bit_identical),
        cache=dict(entries=s_post["entries"], evictions=s_post["evictions"]),
        method=f"ita_batch[{e_hot.step_impl}]",
        note="per-query p50 over micro-batches of B; hot side measured on "
             "a fresh Zipf stream after warming on an earlier one, so "
             "hit_rate is steady-state serving, not replay; full-hit "
             "batches skip the device solve entirely, which is the "
             "speedup_p50 mechanism",
    )


def run_serving(B: int = 16, *, n: int = 40_000, m: int = 240_000,
                xi: float = 1e-8, seed: int = 7, queries: int = 160,
                zipf: float = 1.1, k: int = 5) -> dict:
    """Offered load vs latency through the serving tier (docs/SERVING.md).

    One engine is calibrated (a measured warmup batch fixes the cost
    model's seconds-per-unit), then an open-loop Poisson stream is
    replayed through the full tier — admission, bounded queue, deadline
    batcher, hysteretic degrade ladder — at three offered loads: 0.5x
    and 0.9x the calibrated capacity, and 2.5x (past saturation).  The
    sweep runs on a **virtual clock with modeled batch cost**, so every
    queueing decision is a pure function of (stream seed, load multiple,
    deadline-in-batches): offered loads and the deadline are expressed
    as multiples of the measured batch time, which makes the recorded
    shed/degraded/miss *fractions* machine-independent while the
    absolute ``*_ms`` figures remain honest local measurements.

    The record's claim structure: below saturation nothing is shed and
    nothing degraded; past saturation the bounded queue + token bucket
    shed the excess and the degrade ladder steps down, which is what
    keeps served p99 bounded (``p99_bounded_at_sat`` pins it under the
    worst full-queue drain time) instead of growing with the backlog.
    ``bit_identical`` asserts the low-load pass returned exactly the
    bits a direct ``engine.run`` produces for the same seeds — the tier
    decides when and what to batch, never how to solve.
    """
    from repro.core import TopKQuery
    from repro.serve import (AdmissionPolicy, DegradePolicy, OpenLoopWorkload,
                             PPRService, ServiceConfig, VirtualClock)

    g = web_graph(n, m, dangling_frac=0.15, seed=seed)
    engine = PageRankEngine(g, EnginePlan(step_impl="dense"))
    cfg = BatchConfig(xi=xi)
    queue_cap = 4 * B
    deadline_batches = 4.0    # SLO = 4 measured batch times
    load_mults = [0.5, 0.9, 2.5]

    # calibrate once: the measured batch time is the unit every load and
    # deadline below is expressed in
    probe = PPRService(engine, ServiceConfig(batch_size=B, k=k, cfg=cfg))
    cal = probe.calibrate()
    t_batch = cal["warm_batch_s"]
    spu = cal["seconds_per_unit"]
    capacity_qps = B / t_batch
    deadline_s = deadline_batches * t_batch

    def serve_at(mult: float):
        svc = PPRService(
            engine,
            ServiceConfig(
                batch_size=B, k=k, queue_cap=queue_cap, cfg=cfg,
                # the bucket admits 1.6x capacity: tight enough to shed
                # the bulk of a 2.5x storm, loose enough that sustained
                # queue pressure reaches the degrade ladder (a bucket at
                # exactly 1x would keep the queue empty and the ladder
                # would — correctly — never engage)
                admission=AdmissionPolicy(rate_qps=1.6 * capacity_qps,
                                          burst=float(queue_cap)),
                degrade=DegradePolicy(hi=queue_cap // 2,
                                      lo=queue_cap // 8),
                time_source="model", seconds_per_unit=spu),
            clock=VirtualClock())
        wl = OpenLoopWorkload(g, qps=mult * capacity_qps, n_queries=queries,
                              zipf=zipf, seed=seed, deadline_s=deadline_s,
                              k=k)
        return svc.serve(wl)

    reports = {mult: serve_at(mult) for mult in load_mults}
    loads = []
    for mult in load_mults:
        s = reports[mult].summary()
        loads.append(dict(
            offered_mult=mult,
            offered_qps=mult * capacity_qps,
            served=s["served"], shed=s["shed"],
            shed_frac=s["shed_frac"],
            degraded_frac=s["degraded_frac"],
            deadline_miss_frac=s["deadline_miss_frac"],
            p50_ms=s["latency"]["p50_ms"],
            p99_ms=s["latency"]["p99_ms"],
            qps=s["qps"],
            max_depth=s["queue"]["max_depth"],
            dispatch=dict(full=s["batcher"]["full"],
                          deadline=s["batcher"]["deadline"],
                          flush=s["batcher"]["flush"]),
        ))

    # bit-identity at the healthy load: tier answers == direct engine.run
    low = sorted(reports[load_mults[0]].served, key=lambda x: x.req.req_id)
    seeds_low = np.asarray([x.req.seed for x in low], dtype=np.int64)
    direct = engine.run(TopKQuery(sources=seeds_low, k=k, cfg=cfg)).result
    bit_identical = all(
        np.array_equal(x.indices, np.asarray(direct.indices[i]))
        and np.array_equal(x.scores, np.asarray(direct.scores[i]))
        for i, x in enumerate(low))

    sat = loads[-1]
    # worst honest drain: a request admitted into a full queue waits for
    # queue_cap/B batches plus its own; anything past that must be shed
    p99_bound_ms = (queue_cap / B + 2) * t_batch * 1e3
    return dict(
        bench="serving",
        graph=dict(n=g.n, m=g.m),
        batch=B,
        queries=queries,
        queue_cap=queue_cap,
        zipf=zipf,
        k=k,
        xi=xi,
        platform=jax.default_backend(),
        t_batch_ms=t_batch * 1e3,
        capacity_qps=capacity_qps,
        deadline_batches=deadline_batches,
        deadline_ms=deadline_s * 1e3,
        loads=loads,
        shed_frac_low=loads[0]["shed_frac"],
        shed_frac_sat=sat["shed_frac"],
        degraded_frac_low=loads[0]["degraded_frac"],
        degraded_frac_sat=sat["degraded_frac"],
        p99_low_ms=loads[0]["p99_ms"],
        p99_sat_ms=sat["p99_ms"],
        p99_bounded_at_sat=bool(sat["p99_ms"] <= p99_bound_ms),
        clean_below_saturation=bool(
            loads[0]["shed_frac"] == 0.0 and loads[0]["degraded_frac"] == 0.0
            and loads[1]["shed_frac"] == 0.0),
        overload_protected=bool(sat["shed_frac"] > 0.0
                                and sat["degraded_frac"] > 0.0),
        bit_identical=bool(bit_identical),
        method=f"ita_batch[{engine.step_impl}]",
        note="open-loop Poisson sweep on a virtual clock with modeled "
             "batch cost; loads and deadline are multiples of the "
             "calibrated batch time, so fractions/booleans are "
             "machine-independent and only *_ms fields drift with "
             "hardware; policy = token bucket at 1x capacity + bounded "
             "queue + hysteretic xi-ladder degrade",
    )


def run_ifp(B: int = 8, *, n: int = 4_000, m: int = 24_000,
            xi: float = 1e-10, seed: int = 7, tol: float = 1e-8) -> dict:
    """IFP (both variants) vs forward push vs ITA on the same graph.

    The algorithmic comparison the IFP paper (arXiv 2302.03245) makes:
    iteration counts and the hardware-independent operation counts M(T),
    plus the oracle check against ``reference_pagerank``.  IFP's full
    P' sweep pays more ops per round than threshold-gated forward push
    but needs no active-set bookkeeping — ``ops_ratio_*`` records the
    trade on this graph shape.  Defaults ARE the smoke sizes (like
    ``run_planner_costs``), so the committed baseline is the exact shape
    the CI bench-drift job re-runs; ``B`` is accepted for the shared
    smoke-kwargs interface and unused (single-query solvers).
    """
    from repro.core import forward_push, ifp, reference_pagerank

    del B  # no batch dimension in this record
    g = web_graph(n, m, dangling_frac=0.15, seed=seed)
    pi_ref = reference_pagerank(g)

    def err(r):
        return float(jax.numpy.max(jax.numpy.abs(r.pi - pi_ref)))

    r_ifp1, t_ifp1 = timed(ifp, g, xi=xi, variant="ifp1", repeats=2)
    r_ifp2, t_ifp2 = timed(ifp, g, xi=xi, variant="ifp2", repeats=2)
    r_fp, t_fp = timed(forward_push, g, xi=xi, repeats=2)
    r_ita, t_ita = timed(ita, g, xi=xi, repeats=2)
    return dict(
        bench="ifp",
        graph=dict(n=g.n, m=g.m),
        xi=xi,
        tol=tol,
        platform=jax.default_backend(),
        method="ifp",
        ifp1_us=t_ifp1 * 1e6,
        ifp2_us=t_ifp2 * 1e6,
        forward_push_us=t_fp * 1e6,
        ita_us=t_ita * 1e6,
        ifp1_iterations=int(r_ifp1.iterations),
        ifp2_iterations=int(r_ifp2.iterations),
        forward_push_iterations=int(r_fp.iterations),
        ita_iterations=int(r_ita.iterations),
        ifp1_ops=float(r_ifp1.ops),
        ifp2_ops=float(r_ifp2.ops),
        forward_push_ops=float(r_fp.ops),
        ita_ops=float(r_ita.ops),
        ops_ratio_ifp_vs_fp=float(r_ifp1.ops / max(r_fp.ops, 1.0)),
        ops_ratio_ifp_vs_ita=float(r_ifp1.ops / max(r_ita.ops, 1.0)),
        err_ifp1=err(r_ifp1),
        err_ifp2=err(r_ifp2),
        variants_iteration_match=bool(
            r_ifp1.iterations == r_ifp2.iterations),
        oracle_ok=bool(err(r_ifp1) < tol and err(r_ifp2) < tol),
        note="iteration/op counts are deterministic for a fixed graph "
             "shape (IFP's round count is exactly ceil(log xi / log c)); "
             "wall times carry the usual CPU caveats from "
             "benchmarks/common.py; defaults are the smoke sizes so CI "
             "re-runs the committed shape",
    )


# --smoke sizes for the JSON modes: small enough for a CI drift check
# (minutes, not tens of minutes on one shared CPU), large enough that the
# solves iterate to real convergence.  run_ell_sharded's defaults already
# are its smoke sizes (interpret-mode Pallas, see its docstring).
_SMOKE = dict(B=8, n=4_000, m=24_000)


def _write_json(out: dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--sharded-json", default=None, metavar="PATH",
                    help="write the run_sharded() comparison to PATH "
                         "instead of running the full row matrix")
    ap.add_argument("--query-plan-json", default=None, metavar="PATH",
                    help="write the run_query_plan() engine.run-overhead "
                         "comparison to PATH instead of the row matrix")
    ap.add_argument("--ell-sharded-json", default=None, metavar="PATH",
                    help="write the run_ell_sharded() vertex-sharded "
                         "schedule comparison to PATH instead of the "
                         "row matrix")
    ap.add_argument("--serving-cache-json", default=None, metavar="PATH",
                    help="write the run_serving_cache() cached-vs-uncached "
                         "Zipf-stream comparison to PATH instead of the "
                         "row matrix")
    ap.add_argument("--planner-costs-json", default=None, metavar="PATH",
                    help="write the run_planner_costs() measured-vs-"
                         "declared planner decision + provenance record "
                         "to PATH instead of the row matrix")
    ap.add_argument("--serving-json", default=None, metavar="PATH",
                    help="write the run_serving() offered-load vs latency "
                         "sweep through the serving tier to PATH instead "
                         "of the row matrix")
    ap.add_argument("--ifp-json", default=None, metavar="PATH",
                    help="write the run_ifp() IFP-vs-forward-push-vs-ITA "
                         "iteration/op comparison to PATH instead of the "
                         "row matrix")
    ap.add_argument("--smoke", action="store_true",
                    help="shrink graph/batch for the JSON modes (the CI "
                         "bench-drift shape; committed baselines note "
                         "their own sizes)")
    args = ap.parse_args()
    kw = dict(_SMOKE) if args.smoke else {}
    if args.sharded_json:
        if kw:
            kw["xi"] = 1e-8
        _write_json(run_sharded(**kw), args.sharded_json)
    elif args.query_plan_json:
        if kw:
            kw["xi"] = 1e-8
        _write_json(run_query_plan(**kw), args.query_plan_json)
    elif args.ell_sharded_json:
        _write_json(run_ell_sharded(), args.ell_sharded_json)
    elif args.serving_cache_json:
        if kw:
            kw["queries"] = 96  # defaults already smoke-sized; shorter stream
        _write_json(run_serving_cache(**kw), args.serving_cache_json)
    elif args.planner_costs_json:
        # defaults already are the smoke sizes (see its docstring)
        _write_json(run_planner_costs(**kw), args.planner_costs_json)
    elif args.serving_json:
        if kw:
            kw["xi"] = 1e-8
        _write_json(run_serving(**kw), args.serving_json)
    elif args.ifp_json:
        # defaults already are the smoke sizes (see its docstring)
        _write_json(run_ifp(**kw), args.ifp_json)
    else:
        print("\n".join(run()))
