"""Figure 5 reproduction: RES versus ERR — ITA converges more uniformly.

Paper claim: at equal RES, ITA has smaller max-relative-error than the
power method (because every vertex obeys the same per-vertex h<xi bound,
rather than a global residual).  We sweep matched RES levels and report
the ERR ratio power/ITA (>1 confirms the claim).
"""
from __future__ import annotations

import numpy as np

from repro.core import ita_traced, power_method_traced, reference_pagerank

from .common import csv_row, load_datasets


def run(datasets=None) -> list[str]:
    rows = []
    datasets = datasets or load_datasets()
    for name, g in datasets.items():
        pi_true = reference_pagerank(g)
        r_pow = power_method_traced(g, tol=1e-300, max_iter=200, pi_true=pi_true)
        ratios = []
        for xi in (1e-5, 1e-7, 1e-9):
            r_ita = ita_traced(g, xi=xi, pi_true=pi_true)
            if not r_ita.res_history:
                continue
            res_ita = r_ita.res_history[-1]
            err_ita = r_ita.err_history[-1]  # type: ignore[attr-defined]
            # find the power iteration with the closest RES
            k = int(np.argmin(np.abs(np.log10(np.asarray(r_pow.res_history))
                                     - np.log10(res_ita))))
            err_pow = r_pow.active_history[k]
            if err_ita > 0:
                ratios.append(err_pow / err_ita)
            rows.append(csv_row(
                f"fig5/{name}/xi={xi:g}", 0.0,
                f"RES={res_ita:.2e} ERR_ita={err_ita:.2e} ERR_pow@sameRES={err_pow:.2e}"))
        if ratios:
            rows.append(csv_row(
                f"fig5/{name}", 0.0,
                f"mean_ERRpow/ERRita={np.mean(ratios):.2f} (>1 = ITA more uniform)"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
