"""Figures 2-3 + Table 4 reproduction: ITA versus the power method.

Table 4 of the paper: CPU time until ERR < 1e-3 for SPI (single-thread
power), MPI (multi-thread power) and ITA; the paper reports ITA 1.5-4x
faster than SPI.  On this container both power variants are the same XLA
program (CPU thread count is runtime-controlled), so the comparison is
power-vs-ITA wall time + the hardware-independent operation counts
M(T) (Formula 15) — the quantity the paper's speedup is built on.
"""
from __future__ import annotations


from repro.core import (
    err_max_rel,
    ita_traced,
    power_method_traced,
    reference_pagerank,
)

from .common import csv_row, load_datasets, timed


def time_to_err(g, target=1e-3):
    """Walk down xi/tol until ERR(target) is reached; report wall+ops."""
    pi_true = reference_pagerank(g)

    # power method: iterate, tracking ERR each iteration
    r_pow, wall_pow = timed(
        lambda: power_method_traced(g, tol=1e-300, max_iter=200, pi_true=pi_true))
    err_hist = r_pow.active_history  # ERR trace (see power_method_traced)
    it_pow = next((i + 1 for i, e in enumerate(err_hist) if e < target),
                  len(err_hist))
    ops_pow = (2 * g.m + g.n) * it_pow
    wall_pow_scaled = wall_pow * it_pow / max(r_pow.iterations, 1)

    # ITA: run at successively tighter xi until ERR < target
    for xi in (1e-4, 1e-5, 1e-6, 1e-7, 1e-8):
        r_ita, wall_ita = timed(lambda: ita_traced(g, xi=xi))
        err = float(err_max_rel(r_ita.pi, pi_true))
        if err < target:
            return dict(it_pow=it_pow, ops_pow=ops_pow, wall_pow=wall_pow_scaled,
                        xi=xi, it_ita=r_ita.iterations, ops_ita=r_ita.ops,
                        wall_ita=wall_ita, err_ita=err)
    return dict(it_pow=it_pow, ops_pow=ops_pow, wall_pow=wall_pow_scaled,
                xi=float("nan"), it_ita=-1, ops_ita=float("nan"),
                wall_ita=float("nan"), err_ita=float("nan"))


def run(datasets=None) -> list[str]:
    rows = []
    datasets = datasets or load_datasets()
    for name, g in datasets.items():
        d = time_to_err(g)
        ops_ratio = d["ops_pow"] / d["ops_ita"] if d["ops_ita"] else float("nan")
        wall_ratio = d["wall_pow"] / d["wall_ita"] if d["wall_ita"] else float("nan")
        rows.append(csv_row(
            f"table4/{name}", d["wall_ita"] * 1e6,
            f"ops_power/ops_ita={ops_ratio:.2f} wall_power/wall_ita={wall_ratio:.2f} "
            f"(paper: 1.5-4x) T_pow={d['it_pow']} T_ita={d['it_ita']} xi={d['xi']:g} "
            f"err={d['err_ita']:.2e}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
