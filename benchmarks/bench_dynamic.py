"""§VII future work, delivered: dynamic-graph ITA + prioritized push.

  * incremental update cost vs edit size (warm start from the run
    invariant; ops saving = the skipped global warm-up rounds);
  * Gauss-Southwell top-K push: ops/rounds trade (order freedom §IV).
"""
from __future__ import annotations

import numpy as np

from repro.core.dynamic import ita_incremental, ita_prioritized, ita_residual_state
from repro.graph import graph_from_edges, web_graph

from .common import csv_row, timed


def _edit(g, n_add, n_del, seed):
    rng = np.random.default_rng(seed)
    src, dst = np.asarray(g.src), np.asarray(g.dst)
    keep = np.ones(g.m, bool)
    if n_del:
        keep[rng.choice(g.m, size=n_del, replace=False)] = False
    ns = rng.integers(0, g.n, n_add)
    nd = rng.integers(0, g.n, n_add)
    return graph_from_edges(np.concatenate([src[keep], ns]),
                            np.concatenate([dst[keep], nd]), g.n)


def run(datasets=None) -> list[str]:
    rows = []
    g0 = web_graph(10_000, 80_000, dangling_frac=0.15, seed=0)
    pi_bar, h, ops_full, it_full = ita_residual_state(g0, xi=1e-10)
    rows.append(csv_row("dynamic/fresh_solve", 0.0,
                        f"ops={ops_full:.3e} T={it_full}"))
    for edits in (2, 20, 200):
        g1 = _edit(g0, edits, edits, seed=edits)
        r, wall = timed(lambda: ita_incremental(g0, g1, pi_bar, h, xi=1e-10))
        rows.append(csv_row(
            f"dynamic/edits={edits}", wall * 1e6,
            f"ops={r.ops:.3e} ops_vs_fresh={r.ops/ops_full:.2f} T={r.iterations}"))
    for k_frac, tag in ((1.0, "all"), (0.25, "quarter"), (0.05, "gs5pct")):
        k = max(int(g0.n * k_frac), 1)
        r, wall = timed(lambda: ita_prioritized(g0, xi=1e-8, k=k))
        rows.append(csv_row(
            f"prioritized/k={tag}", wall * 1e6,
            f"ops={r.ops:.3e} T={r.iterations}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
