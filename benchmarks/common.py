"""Shared benchmark utilities.

CPU caveat (EXPERIMENTS.md §Repro): wall-clock numbers are JAX-on-CPU; the
transferable quantities are iteration counts, operation counts (paper
Formula 15) and convergence curves.  Graphs are stat-matched synthetic
stand-ins for the paper's Table-3 datasets at ``SCALE`` of full size.
"""
from __future__ import annotations

import time

import jax

jax.config.update("jax_enable_x64", True)

from repro.graph import TABLE3_PRESETS, paper_dataset  # noqa: E402

SCALE = 0.02
DATASETS = list(TABLE3_PRESETS)


def load_datasets(scale: float = SCALE):
    out = {}
    for name in DATASETS:
        out[name] = paper_dataset(name, scale=scale, seed=0)
    return out


def timed(fn, *args, repeats: int = 1, **kw):
    best = float("inf")
    result = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        result = fn(*args, **kw)
        jax.block_until_ready(getattr(result, "pi", result))
        best = min(best, time.perf_counter() - t0)
    return result, best


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"
