"""Figure 1 reproduction: xi versus RES and T for ITA on the four datasets.

Paper claims (§VI.B):
  (1) RES is linear in xi            (Formula 18: RES ≈ (1-λ)·xi)
  (2) T grows as log(1/xi)           (Formula 14: T = O(log_λ xi))
Checked by fitting log-log / semilog slopes over xi ∈ 1e-4 .. 1e-12.
"""
from __future__ import annotations

import numpy as np

from repro.core import ita_traced

from .common import csv_row, load_datasets, timed


def run(datasets=None) -> list[str]:
    rows = []
    datasets = datasets or load_datasets()
    xis = [1e-4, 1e-6, 1e-8, 1e-10, 1e-12]
    for name, g in datasets.items():
        res_list, iter_list, wall_list = [], [], []
        for xi in xis:
            r, wall = timed(lambda: ita_traced(g, xi=xi))
            res_list.append(max(r.residual, 1e-300))
            iter_list.append(r.iterations)
            wall_list.append(wall)
        # slope of log10(RES) vs log10(xi) — paper predicts ~1 (linear)
        slope_res = np.polyfit(np.log10(xis), np.log10(res_list), 1)[0]
        # T vs log10(1/xi) — paper predicts linear growth
        slope_T = np.polyfit(np.log10(1 / np.asarray(xis)), iter_list, 1)[0]
        rows.append(csv_row(
            f"fig1/{name}", wall_list[-1] * 1e6,
            f"res_slope={slope_res:.2f} (paper: ~1) iters@1e-12={iter_list[-1]} "
            f"dT/dlog10xi={slope_T:.1f}"))
        for xi, res, it, w in zip(xis, res_list, iter_list, wall_list):
            rows.append(csv_row(f"fig1/{name}/xi={xi:g}", w * 1e6,
                                f"RES={res:.3e} T={it}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
